"""Shared benchmark harness: datasets, method suite, measurement helpers.

Sizes default small enough for the CPU container; ``--full`` in run.py scales
up.  All query measurements average over repeated runs (paper: 100 queries x
10 runs; here configurable).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (HNSWCostModel, build_veda, build_effveda,
                        build_vector_storage, build_oracle_store,
                        coordinated_search, independent_search,
                        global_filtered_search, routed_search,
                        hnsw_factory, exact_factory, metrics, SearchStats)
from repro.baselines import FilteredHNSW, SieveIndex, HoneyBeePartitioner
from repro.data import make_retrieval_dataset, RetrievalDataset

CSV_ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.2f},{derived}"
    CSV_ROWS.append(row)
    print(row)


@dataclasses.dataclass
class BenchConfig:
    n_vectors: int = 8000
    dim: int = 24
    n_roles: int = 10
    n_permissions: int = 32
    n_queries: int = 40
    n_runs: int = 3
    k: int = 10
    efs: int = 50
    lam: int = 400
    M: int = 10
    efc: int = 60
    seed: int = 0


_DATASET_CACHE: Dict[Tuple, RetrievalDataset] = {}


def dataset(bc: BenchConfig, sensitivity: float = 1.0,
            name: str = "sift-like") -> RetrievalDataset:
    profile = {
        # dataset profiles loosely mirroring paper Table 2 skews
        "sift-like": dict(block_zipf=(1.0, 1.5), perm_zipf=(2.0, 1.5)),
        "paper-like": dict(block_zipf=(1.0, 2.0), perm_zipf=(2.0, 1.5)),
        "amzn-like": dict(block_zipf=(1.0, 2.0), perm_zipf=(1.0, 1.5)),
    }[name]
    key = (bc.n_vectors, bc.dim, bc.n_roles, bc.n_permissions, bc.n_queries,
           sensitivity, name, bc.seed)
    if key not in _DATASET_CACHE:
        _DATASET_CACHE[key] = make_retrieval_dataset(
            n_vectors=bc.n_vectors, dim=bc.dim, n_roles=bc.n_roles,
            n_permissions=bc.n_permissions, n_queries=bc.n_queries,
            sensitivity=sensitivity, seed=bc.seed, **profile)
    return _DATASET_CACHE[key]


def cost_model(bc: BenchConfig) -> HNSWCostModel:
    return HNSWCostModel(lam_threshold=bc.lam)


def truth_for(ds: RetrievalDataset, k: int) -> List[List[int]]:
    out = []
    for q, r in zip(ds.queries, ds.query_roles):
        t = metrics.brute_force_topk(ds.vectors,
                                     ds.policy.authorized_mask(int(r)), q, k)
        out.append([i for _, i in t])
    return out


def measure_qps(fn: Callable[[np.ndarray, int], Sequence], ds, k: int,
                n_runs: int) -> Tuple[float, float]:
    """Returns (qps, mean_recall)."""
    truths = truth_for(ds, k)
    t0 = time.perf_counter()
    recalls = []
    for _ in range(n_runs):
        for i, (q, r) in enumerate(zip(ds.queries, ds.query_roles)):
            res = fn(q, int(r))
            recalls.append(metrics.recall_at_k(
                [vid for _, vid in res], truths[i], k))
    dt = time.perf_counter() - t0
    n = n_runs * len(ds.queries)
    return n / dt, float(np.mean(recalls))


class MethodSuite:
    """Builds every compared method once over a dataset (HNSW engines)."""

    def __init__(self, bc: BenchConfig, ds: RetrievalDataset,
                 beta: float = 1.1, engines: str = "hnsw"):
        self.bc, self.ds = bc, ds
        cm = cost_model(bc)
        factory = (hnsw_factory(M=bc.M, efc=bc.efc) if engines == "hnsw"
                   else exact_factory())
        t0 = time.perf_counter()
        self.veda = build_veda(ds.policy, cm, beta=beta, k=bc.k)
        self.t_veda = time.perf_counter() - t0
        t0 = time.perf_counter()
        self.effveda = build_effveda(ds.policy, cm, beta=beta, k=bc.k)
        self.t_effveda = time.perf_counter() - t0
        self.veda_store = build_vector_storage(self.veda, ds.vectors,
                                               engine_factory=factory)
        self.eff_store = build_vector_storage(self.effveda, ds.vectors,
                                              engine_factory=factory,
                                              with_global=(engines == "hnsw"))
        t0 = time.perf_counter()
        self.sieve = SieveIndex(ds.policy, cm, beta=beta)
        self.t_sieve = time.perf_counter() - t0
        self.sieve.build_engines(ds.vectors, factory)
        t0 = time.perf_counter()
        self.honeybee = HoneyBeePartitioner(ds.policy, cm, beta=beta)
        self.t_honeybee = time.perf_counter() - t0
        self.honeybee.build_engines(ds.vectors, factory)
        self.global_idx = factory(ds.vectors,
                                  np.arange(len(ds.vectors), dtype=np.int64))
        self.oracle = build_oracle_store(ds.policy, ds.vectors,
                                         engine_factory=factory)
        if engines == "hnsw":
            self.acorn1 = FilteredHNSW(ds.vectors, M=bc.M, efc=bc.efc,
                                       gamma=1)
            self.acorng = FilteredHNSW(ds.vectors, M=bc.M,
                                       efc=max(bc.efc // 2, 20), gamma=3)
        else:
            self.acorn1 = self.acorng = None

    # ------------------------------------------------------- search closures
    def searchers(self, efs: Optional[int] = None) -> Dict[str, Callable]:
        bc = self.bc
        efs = efs or bc.efs
        policy = self.ds.policy
        import math

        def global_search(q, r):
            mask = policy.authorized_mask(r)
            lam = math.ceil(len(mask) / max(int(mask.sum()), 1))
            res = self.global_idx.search(q, max(lam * bc.k, bc.k),
                                         min(lam * efs, len(mask)))
            return [(d, int(i)) for d, i in res if mask[int(i)]][:bc.k]

        out = {
            "global": global_search,
            "oracle": lambda q, r: self.oracle[r].search(q, bc.k, efs),
            "veda": lambda q, r: coordinated_search(
                self.veda_store, q, r, bc.k, efs),
            "effveda": lambda q, r: coordinated_search(
                self.eff_store, q, r, bc.k, efs),
            "sieve": lambda q, r: self.sieve.search(q, r, bc.k, efs),
            "honeybee": lambda q, r: self.honeybee.search(q, r, bc.k, efs),
        }
        if self.acorn1 is not None:
            out["acorn1"] = lambda q, r: self.acorn1.search(
                q, bc.k, efs, allowed=policy.authorized_mask(r))
            out["acorn_g"] = lambda q, r: self.acorng.search(
                q, bc.k, efs, allowed=policy.authorized_mask(r))
        return out
