"""Benchmark orchestrator — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Default sizes finish on the CPU
container; ``--full`` scales toward the paper's setup; ``--only exp05``
runs a single experiment.
"""
from __future__ import annotations

import argparse
import sys
import time

from . import experiments as E
from . import kernels as K
from .common import BenchConfig, MethodSuite, dataset, emit, CSV_ROWS


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow on CPU)")
    ap.add_argument("--only", default=None,
                    help="run a single experiment, e.g. exp05 or kernels")
    ap.add_argument("--out", default=None)
    ap.add_argument("--json", default=None,
                    help="also write a structured JSON report (CI artifact)")
    args = ap.parse_args()

    bc = BenchConfig()
    if args.full:
        bc = BenchConfig(n_vectors=100_000, dim=64, n_roles=32,
                         n_permissions=120, n_queries=100, n_runs=10,
                         lam=2900, M=16, efc=100)

    t0 = time.time()
    want = args.only

    def go(name, fn):
        if want and want not in name:
            return
        print(f"# --- {name} ---", file=sys.stderr)
        fn()

    # construction experiments (cost-model only — fast)
    go("exp01", lambda: E.exp01_build_time(bc))
    go("exp02", lambda: E.exp02_indexed_vs_leftover(bc))
    go("exp03", lambda: E.exp03_n_indices(bc))
    go("exp04", lambda: E.exp04_desired_vs_achieved_sa(bc))
    go("exp05", lambda: E.exp05_qa_vs_sa(bc))
    go("exp07", lambda: E.exp07_indices_per_query(bc))

    # query experiments sharing one engine suite
    suite = None
    needs_suite = [n for n in ("exp06", "exp10", "exp13", "exp14")
                   if (not want or want in n)]
    if needs_suite:
        print("# building method suite (HNSW engines)...", file=sys.stderr)
        suite = MethodSuite(bc, dataset(bc))
        emit("suite_build/veda", suite.t_veda * 1e6, "partition_s")
        emit("suite_build/effveda", suite.t_effveda * 1e6, "partition_s")
        emit("suite_build/sieve", suite.t_sieve * 1e6, "partition_s")
        emit("suite_build/honeybee", suite.t_honeybee * 1e6, "partition_s")
    go("exp06", lambda: E.exp06_purity(bc, suite))
    go("exp08", lambda: E.exp08_lambda_sensitivity(bc))
    go("exp09", lambda: E.exp09_coordinated_effect(bc))
    go("exp10", lambda: E.exp10_efs_sweep(bc, suite))
    go("exp11", lambda: E.exp11_qps_recall_datasets(bc))
    go("exp12", lambda: E.exp12_sensitivity(bc))
    go("exp13", lambda: E.exp13_weighted_workload(bc, suite))
    go("exp14", lambda: E.exp14_multirole(bc, suite))
    go("exp15", lambda: E.exp15_batched_throughput(bc))
    go("exp16", lambda: E.exp16_continuous_batching(bc))
    go("exp17", lambda: E.exp17_role_scaling(bc))
    go("exp18", lambda: E.exp18_sharded_scaling(bc))
    go("exp19", lambda: E.exp19_sustained_churn(bc))
    go("exp20", lambda: E.exp20_slo_serving(bc))
    go("exp21", lambda: E.exp21_drift_reoptimization(bc))
    go("exp22", lambda: E.exp22_filtered_selectivity(bc))

    go("kernels", K.run_all)

    elapsed = time.time() - t0
    print(f"# done in {elapsed:.0f}s, {len(CSV_ROWS)} rows",
          file=sys.stderr)
    if args.out:
        with open(args.out, "w") as f:
            f.write("\n".join(CSV_ROWS) + "\n")
    if args.json:
        import dataclasses
        import json
        rows = []
        for row in CSV_ROWS:
            name, us, derived = row.split(",", 2)
            rec = {"name": name, "us_per_call": float(us)}
            for kv in filter(None, derived.split(";")):
                key, _, val = kv.partition("=")
                try:
                    rec[key] = float(val)
                except ValueError:
                    rec[key] = val
            rows.append(rec)
        with open(args.json, "w") as f:
            json.dump({"config": dataclasses.asdict(bc),
                       "only": args.only, "elapsed_s": round(elapsed, 2),
                       "rows": rows}, f, indent=2)
        print(f"# json report → {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
