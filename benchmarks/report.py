"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dry-run JSONs.

    PYTHONPATH=src python -m benchmarks.report dryrun_1pod.json dryrun_2pod.json
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List


def _gb(x) -> str:
    return f"{x / 2**30:.2f}"


def dryrun_table(records: List[Dict]) -> str:
    rows = ["| arch | shape | mesh | status | compile_s | args_GiB/chip | "
            "temp_GiB/chip | dominant | notes |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in records:
        if r.get("method") == "extrapolated":
            continue
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"SKIP | — | — | — | — | {r['reason'][:60]} |")
            continue
        if r["status"] == "failed":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"FAILED | — | — | — | — | {r['error'][:60]} |")
            continue
        mem = r.get("memory", {})
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | OK | "
            f"{r.get('compile_s', 0)} | "
            f"{_gb(mem.get('argument_size_in_bytes', 0))} | "
            f"{_gb(mem.get('temp_size_in_bytes', 0))} | "
            f"{r.get('dominant', '—')} | |")
    return "\n".join(rows)


def roofline_table(records: List[Dict]) -> str:
    rows = ["| arch | shape | flops/chip | bytes/chip | coll/chip | "
            "compute_t | memory_t | coll_t | dominant | useful | "
            "roofline_frac |",
            "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in records:
        if r.get("method") != "extrapolated" or r["status"] != "ok":
            continue
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['flops_per_chip']:.3e} | "
            f"{r['bytes_per_chip']:.3e} | {r['coll_bytes_per_chip']:.3e} | "
            f"{r['compute_t_s']:.3e} | {r['memory_t_s']:.3e} | "
            f"{r['collective_t_s']:.3e} | {r['dominant']} | "
            f"{r['useful_flops_ratio']:.3f} | "
            f"{r['roofline_fraction']:.4f} |")
    return "\n".join(rows)


def summary(records: List[Dict]) -> str:
    ok = sum(1 for r in records if r["status"] == "ok"
             and r.get("method") != "extrapolated")
    skip = sum(1 for r in records if r["status"] == "skipped")
    fail = sum(1 for r in records if r["status"] == "failed")
    return f"{ok} compiled OK, {skip} skipped (documented), {fail} failed"


def main():
    for path in sys.argv[1:]:
        records = json.load(open(path))
        print(f"\n## {path} — {summary(records)}\n")
        print("### Dry-run (full-depth compile)\n")
        print(dryrun_table(records))
        rl = roofline_table(records)
        if rl.count("\n") > 1:
            print("\n### Roofline (L-extrapolated exact counting)\n")
            print(rl)


if __name__ == "__main__":
    main()
